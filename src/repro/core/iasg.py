"""IASG — Iterate-Averaged Stochastic Gradient MCMC (Algorithm 4).

SGD with a fixed learning rate, viewed as a Markov chain whose stationary
distribution approximates the local posterior (Mandt et al. 2017): run B
burn-in steps, then emit one approximate posterior sample per K-step window
as the Polyak average of that window's iterates.

Everything is ``lax.scan``-based so a client's full local computation is one
compiled program; batches arrive with a leading step axis.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tree_math as tm
from repro.optim import Optimizer

# grad_fn(params, batch) -> (loss, grads)
GradFn = Callable


class IASGResult(NamedTuple):
    """One IASG sampling pass: stacked samples, final iterate, losses."""

    samples: object        # tree, leading axis = num_samples
    params: object         # final iterate (what FedAvg would return)
    opt_state: object
    burn_in_losses: jnp.ndarray
    sample_losses: jnp.ndarray   # (num_samples, steps_per_sample)


def sgd_steps(params, opt: Optimizer, opt_state, grad_fn: GradFn, batches):
    """Plain local SGD over the leading axis of ``batches`` (FedAvg client)."""

    def body(carry, batch):
        p, s = carry
        loss, grads = grad_fn(p, batch)
        updates, s = opt.update(grads, s, p)
        p = tm.tmap(lambda pi, u: pi + u.astype(pi.dtype), p, updates)
        return (p, s), loss

    (params, opt_state), losses = jax.lax.scan(body, (params, opt_state), batches)
    return params, opt_state, losses


def iasg_sample(
    params,
    opt: Optimizer,
    opt_state,
    grad_fn: GradFn,
    batches,
    burn_in_steps: int,
    steps_per_sample: int,
    num_samples: int,
    sample_dtype=jnp.float32,
) -> IASGResult:
    """Algorithm 4. ``batches`` must have leading axis
    burn_in_steps + num_samples * steps_per_sample."""
    total = burn_in_steps + num_samples * steps_per_sample
    lead = jax.tree_util.tree_leaves(batches)[0].shape[0]
    if lead != total:
        raise ValueError(f"need {total} batches, got {lead}")

    split = lambda tree, a, b: tm.tmap(lambda x: x[a:b], tree)

    # --- burn-in: mix the chain into the stationary region -----------------
    burn_losses = jnp.zeros((0,))
    if burn_in_steps:
        params, opt_state, burn_losses = sgd_steps(
            params, opt, opt_state, grad_fn, split(batches, 0, burn_in_steps)
        )

    # --- sampling: one Polyak-averaged sample per window --------------------
    sample_batches = tm.tmap(
        lambda x: x[burn_in_steps:].reshape(
            (num_samples, steps_per_sample) + x.shape[1:]
        ),
        batches,
    )

    def window(carry, window_batches):
        p, s = carry

        def step(inner, batch):
            p, s, acc = inner
            loss, grads = grad_fn(p, batch)
            updates, s = opt.update(grads, s, p)
            p = tm.tmap(lambda pi, u: pi + u.astype(pi.dtype), p, updates)
            acc = tm.tmap(lambda a, pi: a + pi.astype(sample_dtype), acc, p)
            return (p, s, acc), loss

        acc0 = tm.tzeros_like(p, sample_dtype)
        (p, s, acc), losses = jax.lax.scan(step, (p, s, acc0), window_batches)
        sample = tm.tscale(1.0 / steps_per_sample, acc)
        return (p, s), (sample, losses)

    (params, opt_state), (samples, sample_losses) = jax.lax.scan(
        window, (params, opt_state), sample_batches
    )
    return IASGResult(samples, params, opt_state, burn_losses, sample_losses)
