"""MusicGen-medium — decoder-only LM over EnCodec audio tokens [arXiv:2306.05284].

48L d_model=1536 24H (kv=24, i.e. MHA) d_ff=6144 vocab=2048 (EnCodec codebook).
The audio frontend (mel-spectrogram + EnCodec conv codec) is a STUB:
``input_specs()`` provides 64 precomputed text/melody-conditioning embeddings
of shape (B, 64, d_model) consumed via early fusion; the decoder itself
operates on codebook token ids. Full attention: long_500k skipped.
"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    """Build the MusicGen Medium ModelConfig."""
    return ModelConfig(
        name="musicgen-medium",
        arch_type="audio",
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        pattern=(LayerSpec(mixer="attn", ffn="dense"),),
        repeats=48,
        frontend="audio",
        frontend_tokens=64,
        citation="arXiv:2306.05284",
    )
