"""Config registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

Smoke variants are derived mechanically from the full config (<=2 layers,
d_model<=512, <=4 experts) so they always stay in the same architecture
family as the full config — per-arch smoke tests exercise the same code
paths the dry-run lowers at full scale.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs import (
    fedlm_100m,
    gemma3_27b,
    granite_34b,
    internvl2_26b,
    llama4_scout_17b_a16e,
    minitron_4b,
    musicgen_medium,
    qwen3_32b,
    qwen3_moe_30b_a3b,
    recurrentgemma_9b,
    xlstm_125m,
)
from repro.configs.base import (  # noqa: F401 (public re-exports)
    INPUT_SHAPES,
    MULTI_POD,
    SHAPES,
    SINGLE_POD,
    FedConfig,
    LayerSpec,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    replace,
)

#: The ten assigned architectures (public-pool ids) + the framework's own LM.
_MODULES = {
    "xlstm-125m": xlstm_125m,
    "minitron-4b": minitron_4b,
    "musicgen-medium": musicgen_medium,
    "internvl2-26b": internvl2_26b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "granite-34b": granite_34b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "gemma3-27b": gemma3_27b,
    "qwen3-32b": qwen3_32b,
    "fedlm-100m": fedlm_100m,
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "fedlm-100m"]
ALL_ARCHS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    """Return the full-size ModelConfig registered under ``arch``."""
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ALL_ARCHS}")
    return _MODULES[arch].config()


def make_smoke(cfg: ModelConfig) -> ModelConfig:
    """Mechanically reduce a config: 2 layers, d_model<=512, <=4 experts.

    Keeps one period of the layer pattern (truncated to 2 layers) so every
    mixer/ffn kind in the family is exercised.
    """
    layers = cfg.layers()[: max(2, len(cfg.pattern))][:2]
    # Shrink windows so smoke seq lens (~64-128) actually exercise both the
    # in-window and out-of-window code paths.
    layers = tuple(
        dataclasses.replace(s, window=min(s.window, 32) if s.window else 0)
        for s in layers
    )
    d_model = min(cfg.d_model, 256)
    num_heads = 4
    num_kv_heads = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4
    moe = cfg.moe
    if moe.enabled:
        moe = dataclasses.replace(
            moe,
            num_experts=min(moe.num_experts, 4),
            top_k=min(moe.top_k, 2),
            expert_d_ff=min(moe.expert_d_ff, 256),
            shared_expert_d_ff=min(moe.shared_expert_d_ff, 256),
            chunk_tokens=64,
        )
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv_heads,
        head_dim=64,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        pattern=layers,
        repeats=1,
        tail=(),
        moe=moe,
        frontend_tokens=min(cfg.frontend_tokens, 8),
        lru_d=0,
    )


def get_smoke(arch: str) -> ModelConfig:
    """Return the mechanically shrunken smoke variant of ``arch``."""
    return make_smoke(get_config(arch))


def all_configs() -> Dict[str, ModelConfig]:
    """Return every registered arch name mapped to its full config."""
    return {a: get_config(a) for a in ALL_ARCHS}
