"""FedLM-100M — the framework's own ~100M-param dense decoder used by the
end-to-end federated-training example (examples/train_lm_federated.py).

Not part of the assigned pool; sized so a few hundred federated rounds run on
modest hardware while exercising the exact same code paths as the 34B archs.
"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    """Build the the ~100M-param FedLM dense decoder ModelConfig."""
    return ModelConfig(
        name="fedlm-100m",
        arch_type="dense",
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        d_ff=2048,
        vocab_size=32_768,
        pattern=(LayerSpec(mixer="attn", ffn="dense"),),
        repeats=12,
        qk_norm=True,
        citation="this framework",
    )
