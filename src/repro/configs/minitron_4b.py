"""Minitron-4B — width/depth-pruned Nemotron dense decoder [arXiv:2407.14679].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000. Pure full attention:
long_500k decode is skipped (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    """Build the Minitron 4B ModelConfig."""
    return ModelConfig(
        name="minitron-4b",
        arch_type="dense",
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        d_ff=9216,
        vocab_size=256_000,
        pattern=(LayerSpec(mixer="attn", ffn="dense"),),
        repeats=32,
        citation="arXiv:2407.14679",
    )
