"""Config dataclasses for the FedPA framework.

Everything is a frozen dataclass so configs are hashable and usable as jit
static arguments. A model is described as a *pattern* of layers repeated
``repeats`` times plus an optional ``tail`` — this is what lets the model
builder stack parameters per pattern position and ``lax.scan`` over the
repeats, keeping the HLO (and compile time) independent of depth.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

#: Mixer kinds understood by the model builder.
MIXERS = ("attn", "swa", "mlstm", "slstm", "rglru")
#: FFN kinds.
FFNS = ("dense", "moe", "none")


@dataclass(frozen=True)
class LayerSpec:
    """One decoder layer: a sequence mixer followed by an (optional) FFN."""

    mixer: str = "attn"          # one of MIXERS
    ffn: str = "dense"           # one of FFNS
    window: int = 0              # sliding-window size; only used by mixer="swa"

    def __post_init__(self):
        """Reject unknown mixer/ffn names and window-less swa layers."""
        if self.mixer not in MIXERS:
            raise ValueError(f"unknown mixer {self.mixer!r}")
        if self.ffn not in FFNS:
            raise ValueError(f"unknown ffn {self.ffn!r}")
        if self.mixer == "swa" and self.window <= 0:
            raise ValueError("swa mixer requires window > 0")


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (GShard-style one-hot dispatch)."""

    num_experts: int = 0
    top_k: int = 1
    expert_d_ff: int = 0
    shared_expert_d_ff: int = 0   # 0 = no shared expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    # Tokens are routed in chunks of this many tokens (scan over chunks) so the
    # dispatch/combine one-hot tensors stay bounded in VMEM/HBM.
    chunk_tokens: int = 8192
    # "onehot": GShard dense dispatch/combine einsums (baseline — 2TECd flops
    # and (T,E,C) tensors per chunk). "sort": argsort-based gather/scatter
    # routing — O(TKd) data movement, no dispatch flops (§Perf optimization).
    routing: str = "onehot"

    @property
    def enabled(self) -> bool:
        """True when this config actually routes through experts."""
        return self.num_experts > 0


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description for the generic pattern decoder."""

    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[LayerSpec, ...]
    repeats: int
    tail: Tuple[LayerSpec, ...] = ()
    head_dim: int = 0              # 0 -> d_model // num_heads
    moe: MoEConfig = field(default_factory=MoEConfig)
    qk_norm: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    # Modality frontend stub: None | "audio" | "vision".  When set,
    # input_specs() provides precomputed frame/patch embeddings in addition to
    # token ids (early fusion), and the model consumes them directly.
    frontend: Optional[str] = None
    frontend_tokens: int = 0       # number of prefix embedding tokens
    # Whether decode memory/compute is sub-quadratic enough for long_500k.
    supports_long_decode: bool = False
    # §Perf knob: pin a sharding constraint on each mixer/ffn output (the
    # tensor-parallel boundary) so the TP all-reduce happens there, in the
    # compute dtype, instead of being sunk past fp32 converts by SPMD.
    tp_out_constraint: bool = False
    # xLSTM / RG-LRU internals
    conv_width: int = 4            # short conv width for slstm / rglru blocks
    lru_d: int = 0                 # RG-LRU recurrent width (0 -> d_model)
    expansion: float = 2.0         # internal up-projection factor for mlstm/rglru
    citation: str = ""

    def __post_init__(self):
        """Derive head_dim and check head/layer-count consistency."""
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % self.num_kv_heads != 0:
            raise ValueError("num_heads must be divisible by num_kv_heads")
        if len(self.layers()) != self.num_layers:
            raise ValueError(
                f"{self.name}: pattern({len(self.pattern)})x{self.repeats}"
                f"+tail({len(self.tail)}) != derived num_layers"
            )

    # -- derived ------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        """Total decoder layers: pattern x repeats + tail."""
        return len(self.pattern) * self.repeats + len(self.tail)

    def layers(self) -> Tuple[LayerSpec, ...]:
        """The concrete per-layer spec sequence (pattern unrolled + tail)."""
        return self.pattern * self.repeats + self.tail

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so it shards cleanly 16-way and tiles the MXU."""
        return _round_up(self.vocab_size, 128)

    @property
    def q_dim(self) -> int:
        """Query projection width (num_heads x head_dim)."""
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        """Key/value projection width (num_kv_heads x head_dim)."""
        return self.num_kv_heads * self.head_dim

    @property
    def lru_width(self) -> int:
        """RG-LRU recurrent width (lru_d, defaulting to d_model)."""
        return self.lru_d or self.d_model

    # -- bookkeeping ----------------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count via ``jax.eval_shape`` over the real init
        (no allocation; late import avoids a configs<->models cycle)."""
        from repro.models.model import count_params  # noqa: PLC0415
        return count_params(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts) — the N in the
        6ND MODEL_FLOPS roofline term."""
        if not self.moe.enabled:
            return self.param_count()
        m = self.moe
        n_moe_layers = sum(1 for s in self.layers() if s.ffn == "moe")
        inactive = n_moe_layers * (m.num_experts - m.top_k) * 3 * self.d_model * m.expert_d_ff
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    """A named input shape: (seq_len, global_batch) plus train/prefill/decode kind."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES = {s.name: s for s in INPUT_SHAPES}


# ---------------------------------------------------------------------------
# Federated algorithm config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FedConfig:
    """One federated round = ``clients_per_round`` clients x ``local_steps``."""

    # Any name registered with @register_algorithm (repro.algorithms):
    # fedavg | fedpa | mime | fedprox | fedpa_precision | ...
    algorithm: str = "fedpa"
    clients_per_round: int = 16
    local_steps: int = 8           # K: SGD steps per client per round
    # --- FedPA/IASG (Algorithm 4) ---
    burn_in_steps: int = 4         # B: per-round local burn-in steps
    steps_per_sample: int = 2      # K_s: IASG window
    shrinkage_rho: float = 0.1     # rho from Theorem 3
    # --- optimizers ---
    server_opt: str = "sgdm"       # sgd | sgdm | adam | adagrad | yogi
    server_lr: float = 0.5
    server_momentum: float = 0.9
    client_opt: str = "sgdm"
    client_lr: float = 0.01
    client_momentum: float = 0.9
    # burn-in *rounds* (run FedPA in FedAvg regime for first R rounds)
    burn_in_rounds: int = 0
    delta_dtype: str = "float32"
    # --- payload compression (repro.compression) ---
    # "+"-composed codec chain applied to client payloads before they are
    # aggregated: "none" | "lowrank" | "int8" | "lowrank+int8". Only
    # algorithms with supports_codec=True (fedlora) accept a non-"none"
    # codec.
    payload_codec: str = "none"
    # Rank of the per-(round, leaf) low-rank sketch ("lowrank" codec).
    lora_rank: int = 4
    # Bit width of the "int8" codec's symmetric quantizer: 8 or 16.
    quant_bits: int = 8
    # Persist each client's compression error as a residual in the client
    # store and re-inject it at its next participation (error feedback).
    error_feedback: bool = True
    # FedPA: absorb samples into the online/any-time DP as they are produced
    # (Appendix C) instead of stacking them first — saves the l x d sample
    # buffer on the clients.
    streaming_dp: bool = False
    # MIME (Karimireddy et al. 2020): scale of the frozen server-momentum
    # term mixed into local client steps.
    mime_beta: float = 0.9
    # FedProx (Li et al. 2020): proximal strength mu of the client anchor
    # term (mu/2)||theta - theta_0||^2; 0 reduces to FedAvg.
    fedprox_mu: float = 0.1
    # SCAFFOLD (Karimireddy et al. 2020): scale of the server control-variate
    # update c += scale * mean_i(dc_i). The exact rule uses |S|/N
    # (cohort / population); 1.0 is exact under full participation.
    scaffold_c_scale: float = 1.0
    # FedEP (Guo et al. 2023): damping alpha of the per-client natural-
    # parameter site update, site <- (1-alpha)*site + alpha*new. 1.0 makes
    # every round a full site replacement (stateless fedpa_precision).
    fedep_damping: float = 0.5
    # q-FFL (Li et al. 2020): tilt the cohort aggregation toward
    # high-loss clients — client k's weight becomes
    # w_k * max(loss_first_k, 0)**q, renormalized over the cohort
    # (core/round_program.py). q=0 is today's plain weighting, bitwise;
    # larger q trades mean loss for worst-client loss (fairness).
    qffl_q: float = 0.0
    # --- round engine (core/round_program.py) ---
    # How the cohort is laid out inside the one-jit-per-round program:
    # "parallel" (vmap over clients), "sequential" (scan, memory-bound
    # configs), "chunked" (scan-of-vmap; chunk size below).
    round_placement: str = "parallel"
    # Clients vmapped per chunk in the "chunked" placement; 0 = auto
    # (largest power of two <= min(8, clients_per_round)).
    round_chunk_size: int = 0
    # --- async engine (core/async_engine.py) ---
    # Overlap cohort t+1's client compute with round t's server update.
    async_rounds: bool = False
    # Cohorts allowed in flight beyond the one being applied; a delta
    # computed at params version v is applied at version v+s with s <= this.
    # 0 reproduces the synchronous round engine numerically.
    max_staleness: int = 1
    # A staleness-s delta is scaled by staleness_discount**s before the
    # server optimizer sees it (1.0 = no down-weighting).
    staleness_discount: float = 1.0
    # Cohort batch trees stacked ahead of the round loop by a background
    # host thread (data/prefetch.py); 0 = stack inline as before.
    prefetch_rounds: int = 0
    # How prefetched cohorts are decoded off the round loop: "process"
    # (child process + shared-memory arena — numpy decode overlaps even
    # where the GIL would serialize it; requires numpy-leaf batch trees) or
    # "thread" (the in-process fallback; any leaf types).
    prefetch_backend: str = "process"
    # --- fault-injecting cohort simulation (data/cohort_source.py) ---
    # Per-client availability traces: "always" (every client eligible every
    # round — today's ClientSampler behaviour) or "diurnal" (each client is
    # up for an availability_duty fraction of an availability_period-round
    # cycle, with a per-client phase; cohorts draw from the available set).
    availability: str = "always"
    availability_period: int = 24
    availability_duty: float = 0.5
    # P(a sampled client drops mid-round): its half-finished contribution is
    # masked out of the weighted aggregation (survivors renormalize) and its
    # persistent-state write is suppressed. 1.0 = every round all-dropped
    # (zero delta), deterministic per (seed, round).
    dropout_rate: float = 0.0
    # P(a whole cohort misses the round deadline): the async engine applies
    # it late, with straggler lateness added to the staleness exponent of
    # staleness_discount**s. Requires async_rounds=True.
    straggler_rate: float = 0.0
    # Extra rounds of lateness a straggling cohort picks up (uniform in
    # [1, straggler_max_lateness], deterministic per (seed, round)).
    straggler_max_lateness: int = 2
    # Heterogeneous per-client local-step budgets: each sampled client runs
    # a budget drawn uniformly from [min_local_steps, local_steps] (its
    # remaining scheduled steps are frozen — gradients masked to zero,
    # exact only under client_opt="sgd" and a gradient-driven algorithm).
    # 0 = homogeneous budgets (today's behaviour).
    min_local_steps: int = 0
    # --- per-client persistent state (core/client_state.py) ---
    # Where stateful algorithms' per-client state lives: "host" (numpy
    # store, gather/scatter at the round edges — one blocking device sync
    # per stateful round at scatter time) or "device" (dense buffers stay
    # on the accelerator; gather/CAS-scatter are traced inside the jitted
    # round with the cohort ids as an argument — no per-round host sync).
    client_state_placement: str = "host"

    def __post_init__(self):
        """Validate engine/fault knobs, then the algorithm-specific ones."""
        if self.round_placement not in ("parallel", "sequential", "chunked"):
            raise ValueError(
                f"unknown round_placement {self.round_placement!r}")
        # the registered store implementations are the source of truth for
        # valid placements; late import avoids a configs<->core cycle, as
        # does the get_algorithm import below
        from repro.core.client_state import STORES  # noqa: PLC0415
        if self.client_state_placement not in STORES:
            raise ValueError(
                f"unknown client_state_placement "
                f"{self.client_state_placement!r}; known: {tuple(STORES)}")
        if self.round_chunk_size < 0:
            raise ValueError("round_chunk_size must be >= 0")
        if self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        if not 0.0 <= self.staleness_discount <= 1.0:
            raise ValueError("staleness_discount must be in [0, 1]")
        if self.prefetch_rounds < 0:
            raise ValueError("prefetch_rounds must be >= 0")
        if self.prefetch_backend not in ("process", "thread"):
            raise ValueError(
                f"unknown prefetch_backend {self.prefetch_backend!r}; "
                f"known: ('process', 'thread')")
        self._validate_round()
        self._validate_faults()
        self._validate_payload()
        # algorithm-specific checks (and the unknown-algorithm error) live on
        # the registered FedAlgorithm; late import avoids a configs<->core
        # cycle, as does ModelConfig.param_count above
        from repro.algorithms import get_algorithm  # noqa: PLC0415
        get_algorithm(self).validate()

    def _validate_round(self):
        """Range-check the round shape and both optimizer stacks by name.

        Bad values here (a zero-client cohort, a negative learning rate, a
        misspelled optimizer) used to surface only at trace time — or worse,
        silently as NaNs rounds later.
        """
        if self.clients_per_round < 1:
            raise ValueError(
                f"clients_per_round must be >= 1, got "
                f"{self.clients_per_round}")
        if self.burn_in_rounds < 0:
            raise ValueError(
                f"burn_in_rounds must be >= 0, got {self.burn_in_rounds}")
        if not 0.0 < self.shrinkage_rho <= 1.0:
            raise ValueError(
                f"shrinkage_rho must be in (0, 1] (Theorem 3's shrinkage "
                f"coefficient; 0 divides by zero in the DP recursion), got "
                f"{self.shrinkage_rho}")
        if self.server_lr <= 0:
            raise ValueError(f"server_lr must be > 0, got {self.server_lr}")
        if self.client_lr <= 0:
            raise ValueError(f"client_lr must be > 0, got {self.client_lr}")
        if not 0.0 <= self.server_momentum <= 1.0:
            raise ValueError(
                f"server_momentum must be in [0, 1], got "
                f"{self.server_momentum}")
        if not 0.0 <= self.client_momentum <= 1.0:
            raise ValueError(
                f"client_momentum must be in [0, 1], got "
                f"{self.client_momentum}")
        if not (isinstance(self.qffl_q, (int, float))
                and math.isfinite(self.qffl_q) and self.qffl_q >= 0.0):
            raise ValueError(
                f"qffl_q must be a finite float >= 0 (q-FFL's fairness "
                f"exponent; 0 disables the loss tilt), got {self.qffl_q!r}")
        if not isinstance(self.error_feedback, bool):
            raise ValueError(
                f"error_feedback must be a bool (it gates the residual "
                f"slot in the client store), got {self.error_feedback!r}")
        # the optimizer registry is the source of truth for valid names;
        # building both stacks eagerly makes a typo'd server_opt/client_opt
        # raise at config time. Late import avoids a configs<->optim cycle.
        from repro.optim import get_optimizer  # noqa: PLC0415
        try:
            get_optimizer(self.server_opt, self.server_lr,
                          self.server_momentum)
            get_optimizer(self.client_opt, self.client_lr,
                          self.client_momentum)
        except KeyError as e:
            raise ValueError(str(e)) from e

    def _validate_payload(self):
        """Eagerly validate ``delta_dtype`` and the compression knobs by
        name — an unknown dtype/codec string used to surface only as an
        opaque trace-time error deep inside the jitted round."""
        # jnp.dtype, not np.dtype: it resolves the extended float names
        # ("bfloat16") numpy alone rejects; late import keeps config import
        # light
        from jax import numpy as jnp  # noqa: PLC0415
        try:
            dt = jnp.dtype(self.delta_dtype)
        except (TypeError, ValueError) as e:
            raise ValueError(
                f"unknown delta_dtype {self.delta_dtype!r}: not a dtype "
                f"name jnp.dtype understands") from e
        if not jnp.issubdtype(dt, jnp.floating):
            raise ValueError(
                f"delta_dtype must be a floating dtype (it carries deltas "
                f"and posterior statistics), got {self.delta_dtype!r}")
        # the codec registry is the source of truth for valid chains; late
        # import avoids a configs<->compression cycle
        from repro.compression import parse_codec  # noqa: PLC0415
        parse_codec(self.payload_codec)
        if self.lora_rank < 1:
            raise ValueError(f"lora_rank must be >= 1, got {self.lora_rank}")
        if self.quant_bits not in (8, 16):
            raise ValueError(
                f"quant_bits must be 8 or 16, got {self.quant_bits}")

    def _validate_faults(self):
        """Range-check the fault-injection knobs (availability, dropout,
        stragglers, step budgets)."""
        if self.availability not in ("always", "diurnal"):
            raise ValueError(
                f"unknown availability {self.availability!r}; "
                f"known: ('always', 'diurnal')")
        if self.availability_period <= 0:
            raise ValueError("availability_period must be >= 1")
        if not 0.0 < self.availability_duty <= 1.0:
            raise ValueError("availability_duty must be in (0, 1]")
        if not 0.0 <= self.dropout_rate <= 1.0:
            raise ValueError("dropout_rate must be in [0, 1]")
        if not 0.0 <= self.straggler_rate <= 1.0:
            raise ValueError("straggler_rate must be in [0, 1]")
        if self.straggler_rate > 0 and not self.async_rounds:
            raise ValueError(
                "straggler_rate > 0 requires async_rounds=True: a straggling "
                "cohort is handed to the async engine as an extra-stale "
                "delta; the synchronous engine has no late-application path")
        if self.straggler_max_lateness < 1:
            raise ValueError("straggler_max_lateness must be >= 1")
        if self.min_local_steps < 0 or self.min_local_steps > self.local_steps:
            raise ValueError(
                f"min_local_steps must be in [0, local_steps="
                f"{self.local_steps}], got {self.min_local_steps}")
        if self.min_local_steps and self.client_opt != "sgd":
            raise ValueError(
                "min_local_steps > 0 freezes a client's idle steps by "
                "masking gradients, which is exact only under plain "
                f"client_opt='sgd' (got {self.client_opt!r}: a stateful "
                "optimizer would keep moving the params from its buffers)")

    @property
    def num_samples(self) -> int:
        """l: posterior samples per client per round (one per IASG window);
        0 for algorithms without a sampling phase."""
        from repro.algorithms import get_algorithm  # noqa: PLC0415
        return get_algorithm(self).num_samples

    @property
    def fault_injection(self) -> bool:
        """Whether any fault-simulation knob is live. False means the
        engines trace the exact mask-free round programs of a fault-free
        config (zero-rate configs are bitwise-identical to today's)."""
        return (self.availability != "always" or self.dropout_rate > 0
                or self.straggler_rate > 0 or self.min_local_steps > 0)


# ---------------------------------------------------------------------------
# Mesh config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    """Logical device mesh: per-axis extents and their axis names."""

    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        """Total devices in the mesh (product of axis extents)."""
        return math.prod(self.shape)

    @property
    def data_extent(self) -> int:
        """Total client-parallel extent (pod x data)."""
        n = 1
        for ax, s in zip(self.axes, self.shape):
            if ax in ("pod", "data"):
                n *= s
        return n

    @property
    def model_extent(self) -> int:
        """Extent of the "model" axis (1 if the mesh has none)."""
        for ax, s in zip(self.axes, self.shape):
            if ax == "model":
                return s
        return 1


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


def replace(cfg, **kw):
    """dataclasses.replace re-export for convenience."""
    return dataclasses.replace(cfg, **kw)
