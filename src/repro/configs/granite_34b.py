"""Granite-34B-Code — deep llama-architecture code model with MQA
[arXiv:2405.04324].

88L d_model=6144 48H (GQA kv=1, i.e. multi-query) d_ff=24576 vocab=49152.
Pure full attention: long_500k skipped.
"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    """Build the Granite 34B ModelConfig."""
    return ModelConfig(
        name="granite-34b",
        arch_type="dense",
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        d_ff=24_576,
        vocab_size=49_152,
        pattern=(LayerSpec(mixer="attn", ffn="dense"),),
        repeats=88,
        citation="arXiv:2405.04324",
    )
