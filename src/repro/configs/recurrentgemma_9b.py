"""RecurrentGemma-9B — Griffin: RG-LRU recurrent blocks + local attention,
2:1 recurrent:attention [arXiv:2402.19427].

38L d_model=4096 16H (GQA kv=1 on the attention layers) d_ff=12288
vocab=256000. Pattern unit = (rglru, rglru, local-attn[w=2048]) x 12 plus a
2-layer recurrent tail. Recurrent state + bounded attention window give O(1)
decode memory: long_500k runs.
"""
from repro.configs.base import LayerSpec, ModelConfig

WINDOW = 2048


def config() -> ModelConfig:
    """Build the RecurrentGemma 9B ModelConfig."""
    return ModelConfig(
        name="recurrentgemma-9b",
        arch_type="hybrid",
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        d_ff=12_288,
        vocab_size=256_000,
        pattern=(
            LayerSpec(mixer="rglru", ffn="dense"),
            LayerSpec(mixer="rglru", ffn="dense"),
            LayerSpec(mixer="swa", ffn="dense", window=WINDOW),
        ),
        repeats=12,
        tail=(
            LayerSpec(mixer="rglru", ffn="dense"),
            LayerSpec(mixer="rglru", ffn="dense"),
        ),
        expansion=1.5,
        supports_long_decode=True,
        citation="arXiv:2402.19427",
    )
