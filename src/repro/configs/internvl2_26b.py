"""InternVL2-26B — InternViT-6B vision encoder + InternLM2-20B decoder
[arXiv:2404.16821].

We implement the LANGUAGE BACKBONE (48L d_model=6144 48H GQA kv=8 d_ff=16384
vocab=92553). The InternViT encoder + MLP projector is a STUB:
``input_specs()`` provides 256 precomputed patch embeddings (B, 256, d_model)
that the decoder consumes via early-fusion concatenation with the text tokens.
Full attention: long_500k skipped.
"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    """Build the InternVL2 26B ModelConfig."""
    return ModelConfig(
        name="internvl2-26b",
        arch_type="vlm",
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16_384,
        vocab_size=92_553,   # padded to 92_672 (multiple of 128) for sharding
        pattern=(LayerSpec(mixer="attn", ffn="dense"),),
        repeats=48,
        frontend="vision",
        frontend_tokens=256,
        citation="arXiv:2404.16821",
    )
