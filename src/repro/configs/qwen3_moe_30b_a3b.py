"""Qwen3-MoE-30B-A3B — 128 experts, top-8 routing, qk-norm GQA
[hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936.
Expert-parallel: experts shard over the `model` mesh axis (all-to-all
dispatch). Full attention: long_500k skipped.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig


def config() -> ModelConfig:
    """Build the Qwen3-MoE 30B-A3B ModelConfig."""
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        arch_type="moe",
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151_936,
        pattern=(LayerSpec(mixer="attn", ffn="moe"),),
        repeats=48,
        moe=MoEConfig(
            num_experts=128,
            top_k=8,
            expert_d_ff=768,
            capacity_factor=1.25,
            chunk_tokens=8192,
        ),
        qk_norm=True,
        citation="hf:Qwen/Qwen3-30B-A3B",
    )
