"""xLSTM-125M — alternating sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H (GQA kv=4 — heads apply to the mLSTM matrix memory),
d_ff=0 (xLSTM blocks carry their own up/down projections), vocab 50304.
Attention-free: recurrent state gives O(1) decode memory, so long_500k runs.
"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    """Build the xLSTM 125M ModelConfig."""
    return ModelConfig(
        name="xlstm-125m",
        arch_type="ssm",
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50_304,
        pattern=(
            LayerSpec(mixer="slstm", ffn="none"),
            LayerSpec(mixer="mlstm", ffn="none"),
        ),
        repeats=6,
        expansion=2.0,
        supports_long_decode=True,
        citation="arXiv:2405.04517",
    )
