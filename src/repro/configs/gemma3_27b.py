"""Gemma3-27B — 5:1 local:global attention, 128k context, qk-norm
[hf:google/gemma-3-1b-pt].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144. Pattern unit =
5 sliding-window layers (w=1024) + 1 global layer, x10, plus a 2-layer local
tail. The sliding windows bound decode KV memory on 52/62 layers; at
long_500k batch=1 the 10 global layers' cache fits — long_500k runs.
"""
from repro.configs.base import LayerSpec, ModelConfig

WINDOW = 1024


def config() -> ModelConfig:
    """Build the Gemma 3 27B ModelConfig."""
    local = LayerSpec(mixer="swa", ffn="dense", window=WINDOW)
    return ModelConfig(
        name="gemma3-27b",
        arch_type="dense",
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21_504,
        vocab_size=262_144,
        pattern=(local, local, local, local, local,
                 LayerSpec(mixer="attn", ffn="dense")),
        repeats=10,
        tail=(local, local),
        qk_norm=True,
        rope_theta=1_000_000.0,
        supports_long_decode=True,
        citation="hf:google/gemma-3-1b-pt",
    )
