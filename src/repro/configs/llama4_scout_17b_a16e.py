"""Llama-4-Scout-17B-16E — MoE with 16 experts top-1 + shared expert, 3:1
chunked-local:full attention [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048, MoE 16e top-1.
Pattern unit = 3 chunked-local-attention layers (window 8192) + 1 full-
attention layer, x12. The chunked-local layers bound decode KV memory, and at
long_500k batch=1 the full layers' cache fits — long_500k runs.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CHUNK_WINDOW = 8192


def config() -> ModelConfig:
    """Build the Llama 4 Scout 17B-A16E ModelConfig."""
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        arch_type="moe",
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        pattern=(
            LayerSpec(mixer="swa", ffn="moe", window=CHUNK_WINDOW),
            LayerSpec(mixer="swa", ffn="moe", window=CHUNK_WINDOW),
            LayerSpec(mixer="swa", ffn="moe", window=CHUNK_WINDOW),
            LayerSpec(mixer="attn", ffn="moe"),
        ),
        repeats=12,
        moe=MoEConfig(
            num_experts=16,
            top_k=1,
            expert_d_ff=8192,
            shared_expert_d_ff=8192,
            capacity_factor=1.25,
            chunk_tokens=8192,
        ),
        supports_long_decode=True,
        citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
