"""Qwen3-32B — dense decoder with qk-norm GQA [hf:Qwen/Qwen3-8B].

64L d_model=5120 64H (GQA kv=8, head_dim=128) d_ff=25600 vocab=151936.
Pure full attention: long_500k skipped.
"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    """Build the Qwen3 32B ModelConfig."""
    return ModelConfig(
        name="qwen3-32b",
        arch_type="dense",
        d_model=5120,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=25_600,
        vocab_size=151_936,
        pattern=(LayerSpec(mixer="attn", ffn="dense"),),
        repeats=64,
        qk_norm=True,
        rope_theta=1_000_000.0,
        citation="hf:Qwen/Qwen3-8B",
    )
