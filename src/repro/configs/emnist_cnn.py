"""EMNIST-62 CNN — the paper's own benchmark model (Reddi et al. 2020 /
TensorFlow Federated reference: 2 conv layers 3x3 + maxpool + dropout +
128-unit dense + 62-way softmax).

This is NOT a decoder LM, so it has its own small config consumed by
``repro.models.cnn``; it exists for the paper-faithful Table-3-style
simulated benchmark (benchmarks/table3_benchmark_sim.py).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class CNNConfig:
    """Shape of the EMNIST-62 CNN (conv channels, kernel, dense width)."""

    name: str = "emnist-cnn"
    image_size: int = 28
    in_channels: int = 1
    conv_channels: tuple = (32, 64)
    kernel_size: int = 3
    hidden: int = 128
    num_classes: int = 62
    citation: str = "Reddi et al. 2020 (TFF reference model)"


def config() -> CNNConfig:
    """Build the paper-faithful EMNIST-62 CNN config."""
    return CNNConfig()


def smoke() -> CNNConfig:
    """Build a tiny CNN config for fast tests (14x14 inputs, 10 classes)."""
    return CNNConfig(name="emnist-cnn-smoke", image_size=14, conv_channels=(8, 16),
                     hidden=32, num_classes=10)
