"""Test-support shims so the suite collects on a bare interpreter.

``hypothesis`` is the declared dev dependency (requirements-dev.txt) and is
used verbatim when importable. On hermetic containers without it, a minimal
deterministic fallback keeps the property tests *running* instead of
skipping: each ``@given`` test is executed over ``max_examples`` seeded
draws, with the first two draws pinned to the strategy bounds so the edge
cases the real library shrinks toward are always covered.
"""
from __future__ import annotations

import functools

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    class _Strategy:
        """Draw a value in [lo, hi]; draw 0/1 hit the bounds exactly."""

        def __init__(self, lo, hi, cast):
            self.lo, self.hi, self.cast = lo, hi, cast

        def draw(self, rng: np.random.Generator, i: int):
            if i == 0:
                return self.cast(self.lo)
            if i == 1:
                return self.cast(self.hi)
            return self.cast(self.lo + (self.hi - self.lo) * rng.random())

    class _IntStrategy(_Strategy):
        """Integers draw from a small fixed palette (bounds + interior
        points) rather than the full range: array-shape arguments then take
        few distinct values, bounding XLA recompilation across examples."""

        def draw(self, rng: np.random.Generator, i: int):
            lo, hi = int(self.lo), int(self.hi)
            vals = sorted({lo, hi, min(lo + 1, hi), lo + (hi - lo) // 2})
            if i < len(vals):
                return vals[i]
            return vals[int(rng.integers(0, len(vals)))]

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _IntStrategy(min_value, max_value, round)

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(min_value, max_value, float)

    # the fallback is a smoke-level check; the real hypothesis (CI) runs
    # the full example counts
    MAX_FALLBACK_EXAMPLES = 8

    def settings(max_examples: int = 20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = min(max_examples, MAX_FALLBACK_EXAMPLES)
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            import inspect

            params = list(inspect.signature(fn).parameters)
            # strategies fill the TRAILING params (hypothesis semantics);
            # anything before them (e.g. pytest fixtures) passes through
            filled = params[len(params) - len(strats):]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                for i in range(n):
                    rng = np.random.default_rng(1_000_003 * i + 17)
                    drawn = {name: s.draw(rng, i)
                             for name, s in zip(filled, strats)}
                    fn(*args, **kwargs, **drawn)

            # hide the strategy-filled params from pytest's fixture
            # resolution, like the real @given does
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[p for name, p in sig.parameters.items()
                            if name not in filled])
            del wrapper.__wrapped__
            return wrapper

        return deco
